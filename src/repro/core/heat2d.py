"""2D heat equation on a uniform mesh — the paper's §8 validation workload.

The global M×N field is partitioned over a 2D process grid (mprocs × nprocs =
two mesh axes), exactly like the paper's UPC code: each device owns an
(m_loc × n_loc) interior tile; every step exchanges four halo sides and then
applies the 5-point Jacobi update.

The halo exchange is now a consumer of ``repro.comm``: the stencil
neighborhood is an ``AccessPattern`` (``AccessPattern.from_stencil5``) over
the tile-major flattening of the field, and the per-step exchange+stencil
is compiled through a ``repro.comm.schedule.Schedule`` — a gather stage
planned over the *product* of the two mesh axes, an interior compute stage
scheduled inside its collective window (when the split runs), and the
halo-consuming update stage, all in one ``shard_map``.  The condensed plan
works out to exactly the four halo strips (the paper's
``halo_exchange_intrinsic``), but the full ladder applies: ``strategy=``
accepts any rung or ``"auto"`` — ranked on the FULL per-step window cost
(``perfmodel.predict_heat2d_window``: eqs. 19–22 plus the edge-ring
recompute term of the overlap split) for the overlap/condensed pair, by
the generic §5 exchange models for the rest.

Devices at the grid boundary read guaranteed-zero slots, which is harmless:
the update is masked to the global interior, reproducing the paper's
"boundary rows/cols are copied" semantics.

The halo strips are a ``Destination`` descriptor (four named slot tables:
``up`` / ``down`` / ``left`` / ``right``), so by default each step's
``finish`` scatters the landed recv buffer *straight into the strips* —
O(perimeter) unpack work for the O(perimeter) exchange.  Pass
``materialize="full"`` to fall back to assembling the full-length
``mythread_x_copy`` (big_m*big_n elements, the paper's UPCv3 layout) and
indexing the strips out of it — bit-identical results, O(area) buffer
traffic per step.

``overlap=True`` (or ``strategy="overlap"``) splits each step via the
``OverlapHandle`` protocol: the tile-interior update (no halo dependency)
runs while the exchange is in flight; only the one-cell edge ring consumes
the landed halos.  Composes with ``use_kernel=True`` (interior and edge
strips through the Pallas stencil kernel) and with either materialization.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.comm.pattern import AccessPattern, Destination
from repro.comm.plan import Topology

__all__ = ["Heat2D"]


def _halo_indices(big_m, big_n, mprocs, nprocs, zero_slot):
    """Per-rank global ids of the four incoming halo strips (tile-major
    layout, see AccessPattern.from_stencil5); out-of-domain -> zero_slot."""
    m_loc, n_loc = big_m // mprocs, big_n // nprocs
    tile = m_loc * n_loc
    p = mprocs * nprocs
    up = np.full((p, n_loc), zero_slot, np.int32)
    down = np.full((p, n_loc), zero_slot, np.int32)
    left = np.full((p, m_loc), zero_slot, np.int32)
    right = np.full((p, m_loc), zero_slot, np.int32)
    cols = np.arange(n_loc)
    rows = np.arange(m_loc)
    for ip in range(mprocs):
        for kp in range(nprocs):
            r = ip * nprocs + kp
            if ip > 0:      # neighbor above sends its last row
                up[r] = (r - nprocs) * tile + (m_loc - 1) * n_loc + cols
            if ip < mprocs - 1:  # neighbor below sends its first row
                down[r] = (r + nprocs) * tile + cols
            if kp > 0:      # left neighbor sends its last column
                left[r] = (r - 1) * tile + rows * n_loc + (n_loc - 1)
            if kp < nprocs - 1:  # right neighbor sends its first column
                right[r] = (r + 1) * tile + rows * n_loc
    return up, down, left, right


class Heat2D:
    """Distributed 2D heat solver on a (row_axis × col_axis) device grid.

    ``strategy`` picks the gather rung for the halo exchange (default
    ``condensed``; ``"auto"`` lets the §5 models choose); ``overlap=True``
    additionally splits each step into the tile-interior update (which
    needs no halo and can hide the exchange) plus a thin edge-ring update
    that consumes the landed halos — the heat-equation analogue of the SpMV
    ``overlap`` strategy.  ``materialize`` picks the unpack: ``"dest"``
    (default) lands the exchange straight into the four named halo strips
    (O(halo) per step); ``"full"`` assembles the paper's full-length
    ``mythread_x_copy`` first (bit-identical result).
    """

    def __init__(self, mesh, big_m: int, big_n: int, *,
                 row_axis: str = "data", col_axis: str = "model",
                 coef: float = 0.1, use_kernel: bool = False,
                 overlap: bool = False, strategy: str | None = None,
                 blocksize: int | str | None = None,
                 shards_per_node: int | None = None,
                 materialize: str = "dest", hw=None,
                 n_steps_hint: int | None = None):
        if strategy is None:
            strategy = "overlap" if overlap else "condensed"
        assert materialize in ("dest", "full"), materialize
        self.mesh = mesh
        mprocs = mesh.shape[row_axis]
        nprocs = mesh.shape[col_axis]
        assert big_m % mprocs == 0 and big_n % nprocs == 0
        self.mprocs, self.nprocs = mprocs, nprocs
        self.big_m, self.big_n = big_m, big_n
        m_loc, n_loc = big_m // mprocs, big_n // nprocs
        self.spec = P(row_axis, col_axis)
        self.sharding = NamedSharding(mesh, self.spec)
        self.materialize = materialize

        comm_axes = (row_axis, col_axis)
        p = mprocs * nprocs
        n = big_m * big_n
        topo = Topology(p, shards_per_node or p)
        pattern = AccessPattern.from_stencil5(big_m, big_n, mprocs, nprocs)
        destination = None
        if materialize == "dest":
            # the four halo strips ARE the consumer slots: finish() lands
            # the exchange straight into them, no length-n x_copy ever built
            up, down, left, right = _halo_indices(
                big_m, big_n, mprocs, nprocs, zero_slot=Destination.ZERO)
            destination = Destination.from_slots(
                up=up, down=down, left=left, right=right)

        self.predicted_times = None
        if strategy == "auto":
            # ROADMAP refinement: rank overlap vs condensed on the FULL
            # per-step window — eqs. 19–22 plus the edge-ring recompute
            # term of the interior/edge split (the generic §5 exchange
            # models keep pricing the replicate/blockwise rungs; without
            # the ring term the model mispicks overlap on tiles so small
            # the four strip stencils recompute more than the whole tile)
            from repro.comm import plan_cache, select
            from repro.comm.exchange import measure_hw
            from repro.core import perfmodel as pm

            if hw is None:
                hw = measure_hw(mesh, comm_axes)
            bs = blocksize
            if bs == "auto":
                bs = select.choose_blocksize(pattern.indices, n, p,
                                             topology=topo, hw=hw)
            base_plan = plan_cache.get_comm_plan(
                pattern.indices, n, p, blocksize=bs, topology=topo)
            pred = dict(select.rank_strategies(
                base_plan, pattern.r, hw,
                materialize="dest" if destination is not None else None,
                dest_slots=(destination.num_slots
                            if destination is not None else None)))
            w2d = pm.Heat2DWorkload(big_m=big_m, big_n=big_n,
                                    mprocs=mprocs, nprocs=nprocs,
                                    topology=topo)
            win = pm.predict_heat2d_window(
                w2d, hw,
                materialize="full" if materialize == "full" else None)
            # bridge the generic exchange-scale entries onto the window
            # scale before the argmin compares them: shift replicate/
            # blockwise by the delta that maps the generic condensed price
            # to its full-window price, so all four entries carry the same
            # (exchange + whole-tile compute) units
            offset = win["condensed"] - pred["condensed"]
            for rung in ("replicate", "blockwise"):
                pred[rung] = max(pred[rung] + offset, 0.0)
            pred["condensed"] = win["condensed"]
            pred["overlap"] = win["overlap"]
            if n_steps_hint is not None:
                # rank on the n-step steady-state LOOP instead of one call:
                # window setup amortizes away (eq.-23 extension) and the
                # overlap rung earns its double-buffer credit — a rung that
                # wins one dispatch can lose the loop and vice versa
                setup = pm.window_setup_time(topo, hw)
                for rung in ("replicate", "blockwise"):
                    pred[rung] = pm.scan_loop_cost(pred[rung], setup,
                                                   n_steps_hint)
                scn = pm.predict_heat2d_scan(
                    w2d, hw, n_steps_hint,
                    materialize="full" if materialize == "full" else None)
                pred["condensed"] = scn["condensed"]
                pred["overlap"] = scn["overlap"]
            strategy = min(pred, key=pred.get)
            blocksize = bs
            self.predicted_times = pred
        self.strategy = strategy
        # split on the RESOLVED strategy: "auto" may pick overlap, whose
        # predicted win exists only if the interior/edge split actually runs
        self.overlap = overlap or strategy == "overlap"
        split = self.overlap

        # --- the per-step halo exchange + stencil as ONE ExchangeSchedule:
        # the gather stage issues the exchange, the interior stage (when
        # split) runs inside its collective window, the final stage unpacks
        # the landed halos and applies the paper's Listing-8 update
        from repro.comm.schedule import Schedule

        halo_idx = None
        if materialize != "dest":
            # runtime halo index tables into the assembled x_copy; padding
            # reads the guaranteed-zero slot
            halo_idx = _halo_indices(big_m, big_n, mprocs, nprocs,
                                     zero_slot=n + 1)

        def stencil(x):
            if use_kernel:
                from repro.kernels import ops as kops
                return kops.stencil2d(x, coef=coef)
            from repro.kernels import ref as kref
            return kref.stencil2d_ref(x, coef)

        def add_common_stages(sched, *, double_buffer):
            phi_ref = sched.input("phi", spec=self.spec)
            flat = sched.compute(lambda phi: phi.reshape(-1), phi_ref,
                                 name="flatten")
            halo_refs = ()
            if materialize != "dest":
                halo_refs = tuple(
                    sched.constant(a, nm, spec=P(comm_axes))
                    for nm, a in zip(("up_i", "down_i", "left_i", "right_i"),
                                     halo_idx))
            fk = (None if materialize == "dest"
                  else dict(extra_slots=1, copy_own=False))
            if double_buffer:
                g = sched.gather(pattern, double_buffer=True, prime=flat,
                                 destination=destination, name="halo",
                                 finish_kwargs=fk)
            else:
                g = sched.gather(pattern, src=flat, destination=destination,
                                 name="halo", finish_kwargs=fk)
            return phi_ref, g, halo_refs

        def unpack_halos(landed, rest):
            if materialize == "dest":
                return (landed["up"], landed["down"],
                        landed["left"], landed["right"]), rest
            up_i, dn_i, lf_i, rt_i = rest[:4]
            return (landed[up_i[0]], landed[dn_i[0]],
                    landed[lf_i[0]], landed[rt_i[0]]), rest[4:]

        def pad_with_halos(phi, halos):
            up_v, dn_v, lf_v, rt_v = halos
            padded = jnp.zeros((m_loc + 2, n_loc + 2), phi.dtype)
            padded = padded.at[1:-1, 1:-1].set(phi)
            padded = padded.at[0, 1:-1].set(up_v)
            padded = padded.at[-1, 1:-1].set(dn_v)
            padded = padded.at[1:-1, 0].set(lf_v)
            padded = padded.at[1:-1, -1].set(rt_v)
            return padded

        def ring_strips(padded):
            # only the one-cell edge ring consumes the landed halos, via
            # four thin strips of the padded assembly
            top = stencil(padded[0:3, :])[1, 1:-1]
            bottom = stencil(padded[-3:, :])[1, 1:-1]
            left = stencil(padded[:, 0:3])[1:-1, 1]
            right = stencil(padded[:, -3:])[1:-1, 1]
            return top, bottom, left, right

        def interior_mask(phi):
            # global boundary cells keep their value (paper copies the
            # boundary)
            ip = jax.lax.axis_index(row_axis)
            kp = jax.lax.axis_index(col_axis)
            grow = ip * m_loc + jax.lax.broadcasted_iota(jnp.int32,
                                                         phi.shape, 0)
            gcol = kp * n_loc + jax.lax.broadcasted_iota(jnp.int32,
                                                         phi.shape, 1)
            return ((grow > 0) & (grow < big_m - 1)
                    & (gcol > 0) & (gcol < big_n - 1))

        def build_step():
            sched = Schedule()
            phi_ref, g, halo_refs = add_common_stages(sched,
                                                      double_buffer=False)
            inner_refs = ()
            if split:
                # interior update (cells 1..m-2 × 1..n-2) has no halo
                # dependency — it runs inside the exchange window
                inner_refs = (sched.compute(stencil, phi_ref,
                                            name="interior"),)

            def finalize(phi, landed, *rest):
                halos, rest = unpack_halos(landed, rest)
                padded = pad_with_halos(phi, halos)
                # --- compute (paper Listing 8) ---
                if split:
                    (inner,) = rest
                    top, bottom, left, right = ring_strips(padded)
                    upd = inner.at[0, :].set(top).at[-1, :].set(bottom)
                    upd = upd.at[:, 0].set(left).at[:, -1].set(right)
                else:
                    upd = stencil(padded)[1:-1, 1:-1]
                return jnp.where(interior_mask(phi), upd, phi)

            out = sched.compute(finalize, phi_ref, g, *halo_refs,
                                *inner_refs, name="update")
            return sched, phi_ref, out

        def build_scan_overlap():
            # double-buffered body: the delivered halos were issued by the
            # PREVIOUS iteration's feed, so this iteration pays no exchange
            # launch before the ring.  The edge ring is refreshed first,
            # its flattened field feeds the NEXT exchange, and the
            # tile-interior stencil runs inside that freshly opened window
            # (step k+1's gather in flight while step k's interior
            # computes).
            sched = Schedule()
            phi_ref, g, halo_refs = add_common_stages(sched,
                                                      double_buffer=True)

            def ring_half(phi, landed, *rest):
                halos, _ = unpack_halos(landed, rest)
                padded = pad_with_halos(phi, halos)
                top, bottom, left, right = ring_strips(padded)
                half = phi.at[0, :].set(top).at[-1, :].set(bottom)
                half = half.at[:, 0].set(left).at[:, -1].set(right)
                # half's boundary ring now holds step-(k+1) values (masked
                # to the paper's copied global boundary); its interior
                # still holds step k.  The exchange only ever delivers
                # tile-perimeter cells, so feeding half is bit-identical
                # to feeding the finished step-(k+1) field.
                return jnp.where(interior_mask(phi), half, phi)

            half = sched.compute(ring_half, phi_ref, g, *halo_refs,
                                 name="ring_half")
            flat_half = sched.compute(lambda h: h.reshape(-1), half,
                                      name="flatten_half")
            sched.feed(g, flat_half)
            inner = sched.compute(stencil, phi_ref, name="interior")

            def combine(half, inner):
                # local interior cells are never on the global boundary,
                # so only the ring (already masked in half) needs care
                upd = inner.at[0, :].set(half[0, :])
                upd = upd.at[-1, :].set(half[-1, :])
                return upd.at[:, 0].set(half[:, 0]).at[:, -1].set(half[:, -1])

            out = sched.compute(combine, half, inner, name="update")
            return sched, phi_ref, out

        sched, _, out = build_step()
        self.schedule = sched.compile(
            mesh, axis_name=comm_axes, strategy=strategy,
            blocksize=blocksize, topology=topo, hw=hw,
            output=out, out_spec=self.spec)
        self.gather = sched.exchange_of(
            next(s.ref for s in sched._stages if s.kind == "gather"))
        if self.predicted_times is None:
            self.predicted_times = self.gather.predicted_times

        # --- the n-step loop as ONE ScanSchedule: the shard_map window
        # persists across iterations (one plan probe, one hw memo hit,
        # zero per-step host dispatch).  The overlap rung scans the
        # double-buffered body; the other rungs scan the per-step body
        # unchanged.  Sharing the step schedule's resolved plan makes the
        # second resolve a plan-cache memory hit, not a re-probe.
        builder = build_scan_overlap if split else build_step
        sscan, phi_in, sout = builder()
        self.scan_schedule = sscan.scan(
            mesh, carry=phi_in, output=sout, axis_name=comm_axes,
            strategy=strategy, blocksize=self.gather.plan.blocksize,
            topology=topo, hw=hw, n_steps_hint=n_steps_hint)

    @property
    def counts(self):
        return self.gather.counts

    def init_field(self, seed: int = 0) -> jax.Array:
        rng = np.random.default_rng(seed)
        phi = rng.standard_normal((self.big_m, self.big_n)).astype(np.float32)
        return jax.device_put(phi, self.sharding)

    def run(self, phi: jax.Array, steps: int) -> jax.Array:
        """Advance ``steps`` iterations in ONE persistent exchange window
        (``ScanSchedule``): plans resolve once, the hardware memo is probed
        once, and no per-step host dispatch happens inside the loop."""
        return self.scan_schedule(phi, n_steps=steps)

    def reference(self, phi: np.ndarray, steps: int, coef: float = 0.1):
        from repro.kernels import ref as kref
        x = jnp.asarray(phi)
        for _ in range(steps):
            x = kref.stencil2d_ref(x, coef)
        return np.asarray(x)
