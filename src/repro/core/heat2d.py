"""2D heat equation on a uniform mesh — the paper's §8 validation workload.

The global M×N field is partitioned over a 2D process grid (mprocs × nprocs =
two mesh axes), exactly like the paper's UPC code: each device owns an
(m_loc × n_loc) interior tile; every step exchanges four halo sides and then
applies the 5-point Jacobi update.

Halo exchange is the paper's `halo_exchange_intrinsic` mapped to TPU idiom:
  * vertical neighbors: contiguous rows -> plain ``ppermute`` (the paper's
    direct ``upc_memget``; no packing needed),
  * horizontal neighbors: non-contiguous columns -> *pack* into a contiguous
    buffer, ``ppermute``, unpack (the paper's scratch ``xphivec_*`` arrays).

Devices at the grid boundary receive zeros from ppermute (no source), which
is harmless: the update is masked to the global interior, reproducing the
paper's "boundary rows/cols are copied" semantics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat

__all__ = ["Heat2D"]


def _shift(x, axis_name, direction, size):
    """ppermute by +-1 along ``axis_name``; edge devices receive zeros.

    ``size`` is the static axis size (``jax.lax.axis_size`` is not available
    on every supported jax version)."""
    perm = [(i, i + direction) for i in range(size)
            if 0 <= i + direction < size]
    return jax.lax.ppermute(x, axis_name, perm)


def _step_local(phi, *, row_axis, col_axis, mprocs, nprocs, coef,
                use_kernel: bool, overlap: bool = False):
    """phi: (m_loc, n_loc) owned tile. Returns updated tile."""
    m_loc, n_loc = phi.shape
    ip = jax.lax.axis_index(row_axis)
    kp = jax.lax.axis_index(col_axis)

    # --- halo exchange (paper Listing 7) ---
    # vertical: contiguous rows; send my last row down / first row up
    up_halo = _shift(phi[-1:, :], row_axis, +1, mprocs)   # ip-1's last row
    down_halo = _shift(phi[:1, :], row_axis, -1, mprocs)  # ip+1's first row
    # horizontal: pack the column (the paper's phivec scratch), permute
    left_halo = _shift(phi[:, -1:], col_axis, +1, nprocs)   # kp-1's last col
    right_halo = _shift(phi[:, :1], col_axis, -1, nprocs)   # kp+1's first col

    padded = jnp.zeros((m_loc + 2, n_loc + 2), phi.dtype)
    padded = padded.at[1:-1, 1:-1].set(phi)
    padded = padded.at[0, 1:-1].set(up_halo[0])
    padded = padded.at[-1, 1:-1].set(down_halo[0])
    padded = padded.at[1:-1, 0].set(left_halo[:, 0])
    padded = padded.at[1:-1, -1].set(right_halo[:, 0])

    # --- compute (paper Listing 8) ---
    if overlap:
        # overlap rung: the tile-interior update (cells 1..m-2 × 1..n-2)
        # depends only on phi, so it has no data dependency on the four
        # ppermutes above — the scheduler can hide the halo exchange behind
        # it.  Only the one-cell edge ring consumes the landed halos, via
        # four thin strips of `padded`.
        from repro.kernels import ref as kref
        inner = kref.stencil2d_ref(phi, coef)
        top = kref.stencil2d_ref(padded[0:3, :], coef)[1, 1:-1]
        bottom = kref.stencil2d_ref(padded[-3:, :], coef)[1, 1:-1]
        left = kref.stencil2d_ref(padded[:, 0:3], coef)[1:-1, 1]
        right = kref.stencil2d_ref(padded[:, -3:], coef)[1:-1, 1]
        upd = inner.at[0, :].set(top).at[-1, :].set(bottom)
        upd = upd.at[:, 0].set(left).at[:, -1].set(right)
    elif use_kernel:
        from repro.kernels import ops as kops
        upd = kops.stencil2d(padded, coef=coef)[1:-1, 1:-1]
    else:
        from repro.kernels import ref as kref
        upd = kref.stencil2d_ref(padded, coef)[1:-1, 1:-1]

    # mask: global boundary cells keep their value (paper copies boundary)
    grow = ip * m_loc + jax.lax.broadcasted_iota(jnp.int32, phi.shape, 0)
    gcol = kp * n_loc + jax.lax.broadcasted_iota(jnp.int32, phi.shape, 1)
    big_m, big_n = mprocs * m_loc, nprocs * n_loc
    interior = ((grow > 0) & (grow < big_m - 1)
                & (gcol > 0) & (gcol < big_n - 1))
    return jnp.where(interior, upd, phi)


class Heat2D:
    """Distributed 2D heat solver on a (row_axis × col_axis) device grid.

    ``overlap=True`` splits each step into the tile-interior update (which
    needs no halo and can hide the four ppermutes) plus a thin edge-ring
    update that consumes the landed halos — the heat-equation analogue of
    the SpMV ``overlap`` strategy.
    """

    def __init__(self, mesh, big_m: int, big_n: int, *,
                 row_axis: str = "data", col_axis: str = "model",
                 coef: float = 0.1, use_kernel: bool = False,
                 overlap: bool = False):
        if use_kernel and overlap:
            # same rule as DistributedSpMV: the overlap split runs the
            # interior through the jnp path, so a silent combination would
            # benchmark the wrong kernel
            raise ValueError(
                "overlap splits the step into interior + edge strips and "
                "does not compose with use_kernel yet")
        self.mesh = mesh
        self.overlap = overlap
        mprocs = mesh.shape[row_axis]
        nprocs = mesh.shape[col_axis]
        assert big_m % mprocs == 0 and big_n % nprocs == 0
        self.mprocs, self.nprocs = mprocs, nprocs
        self.big_m, self.big_n = big_m, big_n
        self.spec = P(row_axis, col_axis)
        self.sharding = NamedSharding(mesh, self.spec)

        local = functools.partial(
            _step_local, row_axis=row_axis, col_axis=col_axis,
            mprocs=mprocs, nprocs=nprocs, coef=coef, use_kernel=use_kernel,
            overlap=overlap,
        )
        mapped = compat.shard_map(
            local, mesh=mesh, in_specs=self.spec, out_specs=self.spec,
            check_vma=False,
        )

        @functools.partial(jax.jit, static_argnames=("steps",))
        def run(phi, steps: int):
            def body(x, _):
                return mapped(x), None
            out, _ = jax.lax.scan(body, phi, None, length=steps)
            return out

        self._run = run

    def init_field(self, seed: int = 0) -> jax.Array:
        rng = np.random.default_rng(seed)
        phi = rng.standard_normal((self.big_m, self.big_n)).astype(np.float32)
        return jax.device_put(phi, self.sharding)

    def run(self, phi: jax.Array, steps: int) -> jax.Array:
        return self._run(phi, steps)

    def reference(self, phi: np.ndarray, steps: int, coef: float = 0.1):
        from repro.kernels import ref as kref
        x = jnp.asarray(phi)
        for _ in range(steps):
            x = kref.stencil2d_ref(x, coef)
        return np.asarray(x)
