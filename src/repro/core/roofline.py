"""Roofline analysis of compiled XLA programs (deliverable g).

This is the paper's §5 methodology transplanted onto XLA artifacts: count the
exact volumes a program moves (compute bytes from ``cost_analysis``,
communication bytes parsed from the optimized HLO's collective ops) and divide
by a small number of hardware characteristic constants.

Three roofline terms per (arch × shape × mesh), in seconds:
    compute    = HLO_FLOPs            / (chips × PEAK_FLOPS)
    memory     = HLO_bytes            / (chips × HBM_BW)
    collective = Σ collective bytes   / (chips × LINK_BW)   [ICI], plus a
                 separately-reported DCI term for groups spanning pods.

Bytes-moved conventions (per participating device, ring algorithms):
    all-gather          out_bytes × (g-1)/g
    reduce-scatter      out_bytes × (g-1)
    all-reduce          2 × out_bytes × (g-1)/g
    all-to-all          out_bytes × (g-1)/g
    collective-permute  out_bytes
"""
from __future__ import annotations

import dataclasses
import json
import re

import numpy as np

__all__ = ["HW", "CollectiveStats", "RooflineReport", "analyze_compiled",
           "parse_collectives"]

# TPU v5e constants (per chip), from the assignment.
PEAK_FLOPS = 197e12       # bf16
HBM_BW = 819e9            # B/s
ICI_BW = 50e9             # B/s per link; we charge 1 link per collective hop
DCI_BW = 6.25e9           # B/s per chip across the pod boundary (assumption)

HW = {
    "peak_flops": PEAK_FLOPS,
    "hbm_bw": HBM_BW,
    "ici_bw": ICI_BW,
    "dci_bw": DCI_BW,
}

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\((.*)$", re.M
)
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{(\{[0-9,{}\s]*\})\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?"
)
_PAIRS_RE = re.compile(r"source_target_pairs=\{([0-9,{}\s]*)\}")


def _shape_bytes(typestr: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(typestr):
        if dt not in _DTYPE_BYTES:
            continue
        numel = 1
        if dims:
            for d in dims.split(","):
                numel *= int(d)
        total += numel * _DTYPE_BYTES[dt]
    return total


def _parse_groups(attrs: str, num_devices: int) -> list[np.ndarray] | None:
    """Returns the replica groups as arrays of device ids, or None."""
    m = _GROUPS_IOTA_RE.search(attrs)
    if m:
        ngroups, gsize = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(d) for d in m.group(4).split(",")]
            ids = ids.transpose(perm)
        ids = ids.reshape(ngroups, gsize)
        return [ids[i] for i in range(ngroups)]
    m = _GROUPS_EXPLICIT_RE.search(attrs)
    if m:
        txt = m.group(1)
        groups = []
        for grp in re.findall(r"\{([0-9,\s]*)\}", txt):
            if grp.strip():
                groups.append(
                    np.array([int(v) for v in grp.split(",")], dtype=np.int64))
        return groups or None
    m = _PAIRS_RE.search(attrs)
    if m:
        pairs = re.findall(r"\{(\d+),\s*(\d+)\}", m.group(0))
        return [np.array([int(a), int(b)]) for a, b in pairs]
    return None


@dataclasses.dataclass
class CollectiveStats:
    """Per-device communication bytes, by op kind and fabric."""

    ici_bytes: float = 0.0
    dci_bytes: float = 0.0
    by_kind: dict = dataclasses.field(default_factory=dict)
    op_count: int = 0

    def add(self, kind: str, bytes_moved: float, crosses_pod: bool):
        self.op_count += 1
        self.by_kind[kind] = self.by_kind.get(kind, 0.0) + bytes_moved
        if crosses_pod:
            self.dci_bytes += bytes_moved
        else:
            self.ici_bytes += bytes_moved


def parse_collectives(
    hlo_text: str, *, num_devices: int, devices_per_pod: int | None = None
) -> CollectiveStats:
    """Sum per-device bytes moved by every collective in optimized HLO."""
    if devices_per_pod is None:
        devices_per_pod = num_devices
    stats = CollectiveStats()
    for m in _COLL_RE.finditer(hlo_text):
        typestr, kind, attrs = m.group(1), m.group(2), m.group(3)
        kind = kind.replace("-start", "")
        out_bytes = _shape_bytes(typestr)
        if out_bytes == 0:
            continue
        groups = _parse_groups(attrs, num_devices)
        if groups:
            g = max(len(grp) for grp in groups)
            crosses = any(
                (grp // devices_per_pod).min() != (grp // devices_per_pod).max()
                for grp in groups
            )
        else:
            g = num_devices
            crosses = devices_per_pod < num_devices
        g = max(g, 2)
        if kind == "all-gather":
            moved = out_bytes * (g - 1) / g
        elif kind == "reduce-scatter":
            moved = out_bytes * (g - 1)
        elif kind == "all-reduce":
            moved = 2.0 * out_bytes * (g - 1) / g
        elif kind == "all-to-all":
            moved = out_bytes * (g - 1) / g
        elif kind == "collective-permute":
            moved = float(out_bytes)
        else:  # pragma: no cover
            continue
        stats.add(kind, moved, crosses)
    return stats


@dataclasses.dataclass
class RooflineReport:
    name: str
    num_devices: int
    flops_total: float          # whole-program HLO FLOPs (all devices)
    hbm_bytes_per_device: float
    coll: CollectiveStats
    model_flops: float = 0.0    # 6·N·D (dense) or 6·N_active·D (MoE)
    bytes_per_device_peak: float = 0.0   # from memory_analysis
    xla_flops_per_device: float = 0.0    # XLA cost_analysis (cross-check)
    xla_bytes_per_device: float = 0.0

    # --- the three roofline terms, seconds ---
    @property
    def compute_term(self) -> float:
        return self.flops_total / (self.num_devices * PEAK_FLOPS)

    @property
    def memory_term(self) -> float:
        return self.hbm_bytes_per_device / HBM_BW

    @property
    def collective_term(self) -> float:
        return self.coll.ici_bytes / ICI_BW + self.coll.dci_bytes / DCI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_term,
            "memory": self.memory_term,
            "collective": self.collective_term,
        }
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Bulk-synchronous bound: max of the three terms."""
        return max(self.compute_term, self.memory_term, self.collective_term)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — fraction of compiled compute that is
        algorithmically necessary (catches remat/redundancy waste)."""
        return self.model_flops / self.flops_total if self.flops_total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU bound implied by the three-term model."""
        if self.step_time == 0:
            return 0.0
        return (
            self.model_flops
            / (self.num_devices * PEAK_FLOPS)
            / self.step_time
        )

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "num_devices": self.num_devices,
            "flops_total": self.flops_total,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "collective_ici_bytes": self.coll.ici_bytes,
            "collective_dci_bytes": self.coll.dci_bytes,
            "collective_by_kind": self.coll.by_kind,
            "collective_op_count": self.coll.op_count,
            "model_flops": self.model_flops,
            "bytes_per_device_peak": self.bytes_per_device_peak,
            "xla_flops_per_device": self.xla_flops_per_device,
            "xla_bytes_per_device": self.xla_bytes_per_device,
            "compute_term_s": self.compute_term,
            "memory_term_s": self.memory_term,
            "collective_term_s": self.collective_term,
            "dominant": self.dominant,
            "step_time_bound_s": self.step_time,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def analyze_compiled(
    compiled,
    *,
    name: str,
    num_devices: int,
    devices_per_pod: int | None = None,
    model_flops: float = 0.0,
    bf16_program: bool = False,
) -> RooflineReport:
    """Build a RooflineReport from a ``jax.stages.Compiled`` object."""
    # XLA's cost_analysis visits while bodies once (verified empirically), so
    # scanned programs are undercounted by the trip count.  Use our
    # trip-count-aware HLO walker instead; keep XLA's numbers as cross-check.
    from repro.core.hlo_cost import analyze_hlo

    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    hlo = compiled.as_text()
    hc = analyze_hlo(hlo, num_devices=num_devices,
                     devices_per_pod=devices_per_pod or num_devices,
                     bf16_program=bf16_program)
    # walker counts the per-device SPMD module: scale FLOPs to whole-program,
    # keep bytes per-device for the memory term.
    flops = hc.flops * num_devices
    hbm_bytes = hc.hbm_bytes
    coll = CollectiveStats(
        ici_bytes=hc.coll_ici_bytes, dci_bytes=hc.coll_dci_bytes,
        by_kind=hc.coll_by_kind, op_count=int(hc.coll_count))
    mem = {}
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem["peak"] = (
                float(getattr(ma, "temp_size_in_bytes", 0))
                + float(getattr(ma, "argument_size_in_bytes", 0))
                + float(getattr(ma, "output_size_in_bytes", 0))
            )
    except Exception:  # pragma: no cover - backend-dependent
        pass
    report = RooflineReport(
        name=name,
        num_devices=num_devices,
        flops_total=flops,
        hbm_bytes_per_device=hbm_bytes,
        coll=coll,
        model_flops=model_flops,
        bytes_per_device_peak=mem.get("peak", 0.0),
    )
    report.xla_flops_per_device = float(cost.get("flops", 0.0))
    report.xla_bytes_per_device = float(cost.get("bytes accessed", 0.0))
    return report


def save_report(report: RooflineReport, path: str) -> None:
    with open(path, "w") as f:
        json.dump(report.to_json(), f, indent=2)
