"""falcon-mamba-7b [ssm]: 64L d_model=4096 attention-free, vocab=65024,
ssm_state=16 — mamba-1 architecture. [arXiv:2410.05355; unverified]"""
from repro.configs.base import ArchConfig
from repro.configs.registry import reduce_common

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    num_layers=64, d_model=4096, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=65024,
    ssm_state=16,
)


def reduced():
    return reduce_common(CONFIG)
