"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attention + mamba heads.
[arXiv:2411.13676; hf]"""
from repro.configs.base import ArchConfig
from repro.configs.registry import reduce_common

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
    d_ff=5504, vocab_size=32001, head_dim=64,
    ssm_state=16, swa_window=1024,
)


def reduced():
    return reduce_common(CONFIG, num_heads=4, num_kv_heads=2)
