"""The four assigned input shapes and per-arch applicability rules."""
from __future__ import annotations

import dataclasses

__all__ = ["ShapeSpec", "SHAPES", "applicable", "skip_reason"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    mode: str         # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def skip_reason(cfg, shape: ShapeSpec) -> str | None:
    """None = run the cell; else the reason recorded in the roofline table."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("full quadratic attention: 500k decode requires "
                "sub-quadratic context (SSM/SWA) — skipped per assignment")
    return None


def applicable(cfg, shape: ShapeSpec) -> bool:
    return skip_reason(cfg, shape) is None
