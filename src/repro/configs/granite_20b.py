"""granite-20b [dense]: 52L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152 — llama-arch, code. [arXiv:2405.04324; hf]"""
from repro.configs.base import ArchConfig
from repro.configs.registry import reduce_common

CONFIG = ArchConfig(
    name="granite-20b", family="dense",
    num_layers=52, d_model=6144, num_heads=48, num_kv_heads=1,
    d_ff=24576, vocab_size=49152, head_dim=128,
)


def reduced():
    return reduce_common(CONFIG)
