"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 + dense residual FFN.
[hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.configs.base import ArchConfig
from repro.configs.registry import reduce_common

CONFIG = ArchConfig(
    name="arctic-480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=4864, vocab_size=32000, head_dim=128,
    num_experts=128, experts_per_token=2,
    dense_residual=True, residual_d_ff=4864,
)


def reduced():
    return reduce_common(CONFIG)
