"""Architecture configuration schema for the model zoo.

One frozen dataclass describes every assigned architecture; family-specific
fields are zero/None when unused.  ``reduced()`` produces the small smoke-test
variant of the same family (assignment: smoke tests instantiate a reduced
config; full configs are exercised only via the dry-run).
"""
from __future__ import annotations

import dataclasses

__all__ = ["ArchConfig"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str            # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int         # query heads; 0 for attention-free (ssm)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0      # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_dispatch: str = "auto"   # auto | tp_local | ep_a2a (condensed)
    capacity_factor: float = 1.25
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    residual_d_ff: int = 0

    # --- attention flavor ---
    qkv_bias: bool = False        # qwen2.5
    swa_window: int = 0           # 0 = full attention; mixtral/hymba use SWA
    rope_theta: float = 1e4

    # --- SSM (mamba-1) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0          # 0 -> d_model // 16

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0          # precomputed frame count (frontend stub)

    # --- VLM ---
    cross_attn_period: int = 0    # every k-th layer cross-attends to images
    num_image_tokens: int = 0     # precomputed patch embeds (frontend stub)

    act: str = "swiglu"           # swiglu | gelu
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # --- embedding gather strategy (the paper's ladder; DESIGN.md §4) ---
    embed_gather: str = "onehot_psum"   # replicate | onehot_psum

    def __post_init__(self):
        if self.num_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.ssm_state and not self.ssm_dt_rank:
            object.__setattr__(self, "ssm_dt_rank", max(1, self.d_model // 16))

    # ---- derived ----
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_ssm_only(self) -> bool:
        return self.family == "ssm"

    @property
    def is_hybrid(self) -> bool:
        return self.family == "hybrid"

    @property
    def is_encdec(self) -> bool:
        return self.family == "encdec"

    @property
    def is_vlm(self) -> bool:
        return self.family == "vlm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k decode: SSM state or bounded SWA window."""
        return self.ssm_state > 0 or self.swa_window > 0

    def param_count(self) -> tuple[int, int]:
        """(total_params, active_params). Analytic; cross-checked against
        eval_shape in tests."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        n_attn = 0
        if self.num_heads:
            n_attn = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd \
                + self.num_heads * hd * d
            if self.qkv_bias:
                n_attn += (self.num_heads + 2 * self.num_kv_heads) * hd
        n_mlp_dense = (3 if self.act == "swiglu" else 2) * d * f
        n_ssm = 0
        if self.ssm_state:
            di, st, dr = self.d_inner, self.ssm_state, self.ssm_dt_rank
            n_ssm = (d * 2 * di + di * self.ssm_conv + di
                     + di * (dr + 2 * st) + dr * di + di
                     + di * st + di + di * d)
        n_norms = 2 * d

        per_layer_total = n_norms
        per_layer_active = n_norms
        if self.is_moe:
            n_expert = (3 if self.act == "swiglu" else 2) * d * f
            n_router = d * self.num_experts
            per_layer_total += n_attn + n_router + self.num_experts * n_expert
            per_layer_active += n_attn + n_router \
                + self.experts_per_token * n_expert
            if self.dense_residual:
                rff = (3 if self.act == "swiglu" else 2) * d * self.residual_d_ff
                per_layer_total += rff
                per_layer_active += rff
        elif self.is_ssm_only:
            per_layer_total += n_ssm
            per_layer_active += n_ssm
        elif self.is_hybrid:
            per_layer_total += n_attn + n_ssm + n_mlp_dense
            per_layer_active += n_attn + n_ssm + n_mlp_dense
        else:
            per_layer_total += n_attn + n_mlp_dense
            per_layer_active += n_attn + n_mlp_dense

        total = self.num_layers * per_layer_total
        active = self.num_layers * per_layer_active

        # VLM: every period-th layer is a cross-attn block with the same
        # parameter volume as a dense block (attn shapes match) — no extra.

        if self.is_encdec:
            enc_layer = n_attn + n_mlp_dense + n_norms
            total += self.encoder_layers * enc_layer
            active += self.encoder_layers * enc_layer
            # decoder cross-attn per layer
            total += self.num_layers * (n_attn + d)
            active += self.num_layers * (n_attn + d)

        emb = v * d * (1 if self.tie_embeddings else 2)
        total += emb + d  # final norm
        active += emb + d
        return int(total), int(active)

    def flops_param_count(self) -> int:
        """Active params excluding the embedding table (gather, ~0 flops);
        the head matmul is charged separately by callers that compute full
        logits.  This is the N in MODEL_FLOPS = 6·N·tokens."""
        _, active = self.param_count()
        return int(active - self.vocab_size * self.d_model
                   * (1 if self.tie_embeddings else 2))
