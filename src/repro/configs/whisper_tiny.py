"""whisper-tiny [audio enc-dec]: 4L d_model=384 6H (kv=6) d_ff=1536
vocab=51865 — conv frontend is a STUB (input_specs provides precomputed
frame embeddings, 1500 frames). [arXiv:2212.04356; unverified]"""
from repro.configs.base import ArchConfig
from repro.configs.registry import reduce_common

CONFIG = ArchConfig(
    name="whisper-tiny", family="encdec",
    num_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
    d_ff=1536, vocab_size=51865, head_dim=64,
    encoder_layers=4, encoder_seq=1500,
    act="gelu", norm="layernorm",
)


def reduced():
    return reduce_common(CONFIG)
