"""Architecture registry: the 10 assigned configs + the paper's SpMV problems.

``get_config(name)`` returns the exact published configuration;
``get_config(name, reduced=True)`` returns the same-family smoke-test variant
(small widths/layers/experts/vocab) used by tests on CPU.
"""
from __future__ import annotations

import dataclasses
import importlib

__all__ = ["ARCH_NAMES", "get_config"]

ARCH_NAMES = (
    "mixtral-8x22b",
    "arctic-480b",
    "granite-20b",
    "minitron-4b",
    "qwen2.5-32b",
    "llama3-8b",
    "hymba-1.5b",
    "falcon-mamba-7b",
    "whisper-tiny",
    "llama-3.2-vision-90b",
)

_MODULES = {name: name.replace("-", "_").replace(".", "_")
            for name in ARCH_NAMES}


def get_config(name: str, *, reduced: bool = False):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    cfg = mod.CONFIG
    if reduced:
        cfg = mod.reduced()
    return cfg


def reduce_common(cfg, **over):
    """Default reduction: tiny widths, few layers, small vocab; preserves
    family, attention flavor, MoE/SSM structure."""
    num_heads = min(cfg.num_heads, 4) if cfg.num_heads else 0
    num_kv = min(cfg.num_kv_heads, num_heads) if num_heads else 0
    if num_heads and cfg.num_kv_heads == 1:
        num_kv = 1  # preserve MQA
    upd = dict(
        num_layers=min(cfg.num_layers, 2),
        d_model=64,
        num_heads=num_heads,
        num_kv_heads=num_kv,
        head_dim=16 if num_heads else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        num_experts=min(cfg.num_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        residual_d_ff=64 if cfg.dense_residual else 0,
        swa_window=16 if cfg.swa_window else 0,
        ssm_state=min(cfg.ssm_state, 8),
        ssm_dt_rank=8 if cfg.ssm_state else 0,
        encoder_layers=min(cfg.encoder_layers, 2),
        encoder_seq=32 if cfg.encoder_seq else 0,
        cross_attn_period=min(cfg.cross_attn_period, 2),
        num_image_tokens=16 if cfg.num_image_tokens else 0,
    )
    upd.update(over)
    return dataclasses.replace(cfg, **upd)
